"""Regenerate tests/data/trace_golden.json — sha256 pins of every named
scenario / fleet / placement trace on the DEFAULT (numpy) backend.

Run from the repo root:

    PYTHONPATH=src python tools/gen_trace_goldens.py [--only SUBSTR]

The pins freeze the canonical `to_json()` bytes of the traces the
replay tests exercise, so a refactor of the water-fill / optimizer hot
path (PR 6's fused tick) can prove the default path is byte-identical
PRE-vs-POST, not merely self-consistent run-to-run. Only regenerate
when a trace change is intentional and reviewed.

``--only SUBSTR`` regenerates just the pins whose key contains SUBSTR
(e.g. ``--only fleet_churn`` or ``--only placement/``), merging them
into the existing golden file — adding one scenario no longer pays the
full-library regen. Matching is by key substring AFTER the runs are
enumerated, so an `--only` that matches nothing fails loudly instead
of silently writing an unchanged file.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys


def _sha(text: str) -> str:
    return hashlib.sha256(text.encode()).hexdigest()


def _runners() -> dict:
    """{pin key: zero-arg runner returning the trace json} for every
    pinned trace (lazy: nothing runs until the runner is called)."""
    from repro.fleet.scenario import fleet_scenario_names, \
        get_fleet_scenario, run_fleet_scenario
    from repro.placement import run_placement_scenario, scan_agg, \
        two_stage_join
    from repro.scenarios import get_scenario, run_scenario, scenario_names

    runners = {}
    for name in scenario_names():
        runners[f"scenario/{name}/seed3"] = (
            lambda n=name: run_scenario(get_scenario(n),
                                        seed=3).trace.to_json())
    for name in fleet_scenario_names():
        runners[f"fleet/{name}/seed3"] = (
            lambda n=name: run_fleet_scenario(get_fleet_scenario(n),
                                              seed=3).trace.to_json())
    for backend in ("wanify", "static"):
        runners[f"placement/skew_ramp/{backend}/seed3"] = (
            lambda b=backend: run_placement_scenario(
                "skew_ramp", query=two_stage_join(4), seed=3,
                backend=b).trace.to_json())
    runners["placement/runtime_fluctuation/wanify/seed5"] = (
        lambda: run_placement_scenario(
            "runtime_fluctuation", query=scan_agg(4),
            seed=5).trace.to_json())
    return runners


def collect(only: str | None = None) -> dict:
    """Run the pinned traces and return {key: sha256-of-json};
    `only` filters keys by substring (error when nothing matches)."""
    runners = _runners()
    if only is not None:
        runners = {k: v for k, v in runners.items() if only in k}
        if not runners:
            raise SystemExit(f"--only {only!r} matches no pin key")
    return {k: _sha(run()) for k, run in runners.items()}


def main() -> None:
    """Write (or merge into) the golden document next to the test data."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--only", type=str, default=None, metavar="SUBSTR",
                    help="regenerate only pins whose key contains "
                         "SUBSTR, merged into the existing file")
    args = ap.parse_args()
    path = os.path.abspath(
        os.path.join(os.path.dirname(__file__), os.pardir,
                     "tests", "data", "trace_golden.json"))
    hashes = {}
    if args.only is not None and os.path.exists(path):
        with open(path) as f:
            hashes = json.load(f)["hashes"]
    fresh = collect(only=args.only)
    hashes.update(fresh)
    doc = {"comment": "sha256 of trace.to_json() per named run; "
                      "regenerate via tools/gen_trace_goldens.py",
           "hashes": hashes}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    sys.stderr.write(f"wrote {path} ({len(fresh)} regenerated, "
                     f"{len(hashes)} pins total)\n")


if __name__ == "__main__":
    main()
