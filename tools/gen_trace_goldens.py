"""Regenerate tests/data/trace_golden.json — sha256 pins of every named
scenario / fleet / placement trace on the DEFAULT (numpy) backend.

Run from the repo root:

    PYTHONPATH=src python tools/gen_trace_goldens.py

The pins freeze the canonical `to_json()` bytes of the traces the
replay tests exercise, so a refactor of the water-fill / optimizer hot
path (PR 6's fused tick) can prove the default path is byte-identical
PRE-vs-POST, not merely self-consistent run-to-run. Only regenerate
when a trace change is intentional and reviewed.
"""
from __future__ import annotations

import hashlib
import json
import os
import sys


def _sha(text: str) -> str:
    return hashlib.sha256(text.encode()).hexdigest()


def collect() -> dict:
    """Run every pinned trace and return {key: sha256-of-json}."""
    from repro.fleet.scenario import fleet_scenario_names, \
        get_fleet_scenario, run_fleet_scenario
    from repro.placement import run_placement_scenario, scan_agg, \
        two_stage_join
    from repro.scenarios import get_scenario, run_scenario, scenario_names

    out = {}
    for name in scenario_names():
        res = run_scenario(get_scenario(name), seed=3)
        out[f"scenario/{name}/seed3"] = _sha(res.trace.to_json())
    for name in fleet_scenario_names():
        res = run_fleet_scenario(get_fleet_scenario(name), seed=3)
        out[f"fleet/{name}/seed3"] = _sha(res.trace.to_json())
    for backend in ("wanify", "static"):
        res = run_placement_scenario("skew_ramp", query=two_stage_join(4),
                                     seed=3, backend=backend)
        out[f"placement/skew_ramp/{backend}/seed3"] = \
            _sha(res.trace.to_json())
    res = run_placement_scenario("runtime_fluctuation", query=scan_agg(4),
                                 seed=5)
    out["placement/runtime_fluctuation/wanify/seed5"] = \
        _sha(res.trace.to_json())
    return out


def main() -> None:
    """Write the golden document next to the test data."""
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "tests", "data", "trace_golden.json")
    doc = {"comment": "sha256 of trace.to_json() per named run; "
                      "regenerate via tools/gen_trace_goldens.py",
           "hashes": collect()}
    with open(os.path.abspath(path), "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    sys.stderr.write(f"wrote {os.path.abspath(path)} "
                     f"({len(doc['hashes'])} pins)\n")


if __name__ == "__main__":
    main()
