"""Docs reference checker (CI `docs` job).

Every module path or dotted `repro.*` name mentioned in
`docs/paper_map.md` and `DESIGN.md` must exist in the tree, and every
`tests/...py::test_name` reference must name a real test function —
documentation that points at renamed or deleted code fails the build.

Run:  python tools/check_docs.py   (from the repo root; no deps)
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOCS = ["docs/paper_map.md", "DESIGN.md", "README.md"]

# backtick-quoted references we verify:
PATH_RE = re.compile(r"`((?:src|tests|benchmarks|examples|tools|docs)/"
                     r"[\w/.-]+?\.(?:py|md))(?:::(\w+))?`")
MODULE_RE = re.compile(r"`(repro(?:\.\w+)+)`")


def module_exists(dotted: str) -> bool:
    """True if `repro.a.b` resolves to src/repro/a/b.py or a package."""
    rel = Path("src", *dotted.split("."))
    return (ROOT / rel).with_suffix(".py").exists() or \
        (ROOT / rel / "__init__.py").exists()


def test_function_exists(path: Path, name: str) -> bool:
    """True if `def <name>(` appears in the referenced test file."""
    try:
        text = path.read_text()
    except OSError:
        return False
    return re.search(rf"^def {re.escape(name)}\(", text, re.M) is not None


def check() -> int:
    """Scan the doc set; returns the number of dangling references."""
    bad = 0
    for doc in DOCS:
        text = (ROOT / doc).read_text()
        for m in PATH_RE.finditer(text):
            rel, func = m.group(1), m.group(2)
            target = ROOT / rel
            if not target.exists():
                print(f"{doc}: missing file `{rel}`")
                bad += 1
            elif func and not test_function_exists(target, func):
                print(f"{doc}: `{rel}` has no function `{func}`")
                bad += 1
        for m in MODULE_RE.finditer(text):
            if not module_exists(m.group(1)):
                print(f"{doc}: missing module `{m.group(1)}`")
                bad += 1
    if bad:
        print(f"check_docs: {bad} dangling reference(s)")
    else:
        print("check_docs: all documentation references resolve")
    return bad


if __name__ == "__main__":
    sys.exit(1 if check() else 0)
